"""Serving metrics storage (``repro.serving.metrics``).

Contracts:

* **log₂ histograms are O(1)-memory percentile sketches** — exact
  ``n``/``sum``/``min``/``max``; p50/p95/p99 within one bucket width of
  the exact list-based :func:`percentiles` (the golden test);
* **ServeMetrics is thread-safe** — N hammering threads never lose a
  count and ``report()`` can interleave with recording;
* **deadline_miss_rate counts shed requests** — a request shed at
  dequeue is a missed deadline even though it never completed;
* **event timelines carry injectable-clock ``t_s`` stamps**;
* **metrics_text() is valid Prometheus exposition** with stable ``le``
  edges and exact ``_sum``/``_count``.
"""
import threading

import numpy as np
import pytest

from repro import serving as SV
from repro.serving.metrics import Log2Histogram, MetricsWriter, percentiles


# --------------------------------------------------------------------------
# Log2Histogram
# --------------------------------------------------------------------------


def test_histogram_empty():
    h = Log2Histogram()
    assert h.summary() == {"n": 0}
    assert h.percentile(50) is None


def test_histogram_single_sample_is_exact():
    h = Log2Histogram()
    h.record(0.125)
    s = h.summary()
    assert s["n"] == 1
    # with one sample every percentile collapses to it (vmin == vmax)
    assert s["p50_ms"] == s["p99_ms"] == s["max_ms"] == 125.0
    assert s["mean_ms"] == 125.0


def test_histogram_exact_aggregates():
    h = Log2Histogram()
    xs = [0.001, 0.010, 0.500, 7.0, 0.0042]
    for v in xs:
        h.record(v)
    assert h.n == 5
    assert h.total == pytest.approx(sum(xs))
    assert h.vmin == pytest.approx(min(xs))
    assert h.vmax == pytest.approx(max(xs))


def test_histogram_underflow_and_overflow_buckets():
    h = Log2Histogram(base=1e-5, octaves=26, sub=8)
    h.record(0.0)          # <= 0: bucket 0
    h.record(-1.0)         # negative: bucket 0, min stays exact
    h.record(1e-9)         # below base: bucket 0
    h.record(1e9)          # beyond the last octave: last bucket
    assert h.counts[0] == 3
    assert h.counts[-1] == 1
    assert h.vmin == -1.0 and h.vmax == 1e9
    # percentiles stay inside the observed range even for the absorbers
    assert -1.0 <= h.percentile(50) <= 1e9


def test_histogram_bucket_boundaries_route_consistently():
    """A value on an exact bucket edge lands in the bucket whose
    half-open range [lo, hi) contains it."""
    h = Log2Histogram(base=1e-5, octaves=26, sub=8)
    for v in (1e-5, 2e-5, 4e-5, 1e-5 * (1 + 1 / 8), 0.1, 1.0, 3.3):
        idx = h._index(v)
        lo, hi = h.bucket_bounds(idx)
        assert lo <= v < hi or (idx == len(h.counts) - 1 and v >= lo), \
            f"v={v} idx={idx} bounds=({lo}, {hi})"


def test_histogram_index_monotone():
    h = Log2Histogram()
    vals = np.geomspace(1e-6, 500.0, 4000)
    idxs = [h._index(float(v)) for v in vals]
    assert idxs == sorted(idxs)
    assert max(idxs) < len(h.counts)


def test_histogram_percentiles_match_exact_within_one_bucket():
    """The golden test: histogram p50/p95/p99 vs list-based percentiles
    on lognormal latencies — error bounded by one bucket width."""
    rng = np.random.default_rng(0)
    xs = np.exp(rng.normal(np.log(0.050), 1.0, 5000))  # ~50ms lognormal
    h = Log2Histogram()
    for v in xs:
        h.record(float(v))
    exact = percentiles(xs)
    approx = h.summary()
    assert approx["n"] == exact["n"] == 5000
    assert approx["mean_ms"] == pytest.approx(exact["mean_ms"], rel=1e-6)
    assert approx["max_ms"] == pytest.approx(exact["max_ms"], rel=1e-6)
    for key in ("p50_ms", "p95_ms", "p99_ms"):
        got, want = approx[key], exact[key]
        lo, hi = h.bucket_bounds(h._index(want / 1e3))
        width_ms = (hi - lo) * 1e3
        assert abs(got - want) <= width_ms, \
            f"{key}: {got} vs {want} (bucket width {width_ms:.3f}ms)"


def test_histogram_cumulative_octaves_monotone_and_complete():
    h = Log2Histogram()
    for v in (0.001, 0.002, 0.004, 0.1, 2.0):
        h.record(v)
    edges = h.cumulative_octaves()
    assert len(edges) == h.octaves
    les = [le for le, _ in edges]
    cums = [c for _, c in edges]
    assert les == sorted(les)
    assert cums == sorted(cums)
    assert cums[-1] == h.n


def test_histogram_shape_validation():
    with pytest.raises(ValueError):
        Log2Histogram(base=0.0)
    with pytest.raises(ValueError):
        Log2Histogram(octaves=0)
    with pytest.raises(ValueError):
        Log2Histogram(sub=0)


# --------------------------------------------------------------------------
# ServeMetrics
# --------------------------------------------------------------------------


def test_latency_report_matches_histogram():
    m = SV.ServeMetrics()
    for v in (0.010, 0.020, 0.030, 0.100):
        m.record_request(v, tier="top")
    m.record_batch("top", 4, 0.1)  # per_tier rows key off served batches
    rep = m.report()
    assert rep["latency_ms"]["n"] == 4
    assert rep["per_tier"]["top"]["latency_ms"]["n"] == 4
    assert rep["latency_ms"]["max_ms"] == pytest.approx(100.0)


def test_deadline_miss_rate_counts_shed():
    """3 completed (1 missed) + 1 shed → 2 misses over 4 requests."""
    m = SV.ServeMetrics()
    m.record_request(0.010)
    m.record_request(0.020, deadline_missed=True)
    m.record_request(0.030)
    m.record_deadline_shed()
    rep = m.report()
    assert rep["requests"] == 3
    assert rep["deadline_misses"] == 1
    assert rep["deadline_shed"] == 1
    assert rep["deadline_miss_rate"] == pytest.approx(0.5)


def test_deadline_miss_rate_zero_requests():
    assert SV.ServeMetrics().report()["deadline_miss_rate"] == 0.0


def test_event_timelines_stamped_with_injected_clock():
    t = [100.0]
    m = SV.ServeMetrics(clock=lambda: t[0])
    t[0] = 101.5
    m.record_switch(3, "top", "b32", "queue depth 9")
    t[0] = 104.25
    m.record_breaker("closed", "open", "executor storm")
    rep = m.report()
    assert rep["tier_switches"][0]["t_s"] == pytest.approx(1.5)
    assert rep["breaker_timeline"][0]["t_s"] == pytest.approx(4.25)
    assert rep["breaker_timeline"][0]["seq"] == 0


def test_concurrent_recording_never_loses_counts():
    """8 threads hammer every hook; totals must be exact and report()
    must be callable mid-storm without tearing."""
    m = SV.ServeMetrics()
    n_threads, per_thread = 8, 500
    stop = threading.Event()

    def reader():
        while not stop.is_set():
            m.report()
            m.metrics_text()

    def writer(k):
        for i in range(per_thread):
            m.record_request(0.001 * (i % 50 + 1), tier=f"t{k % 2}",
                             deadline_missed=(i % 10 == 0))
            m.record_batch(f"t{k % 2}", 2, 0.001, slots=4, cell="c")
            m.record_failure("codec")
            m.record_rejected()

    rt = threading.Thread(target=reader, daemon=True)
    rt.start()
    ts = [threading.Thread(target=writer, args=(k,))
          for k in range(n_threads)]
    for th in ts:
        th.start()
    for th in ts:
        th.join()
    stop.set()
    rt.join(timeout=10)
    total = n_threads * per_thread
    rep = m.report()
    assert rep["requests"] == total
    assert rep["latency_ms"]["n"] == total
    assert rep["rejected"] == total
    assert rep["failures_total"]["codec"] == total
    assert rep["deadline_misses"] == total // 10
    assert sum(t["images"] for t in rep["per_tier"].values()) == 2 * total
    assert sum(h.n for h in m._per_tier_lat.values()) == total


# --------------------------------------------------------------------------
# Prometheus exposition
# --------------------------------------------------------------------------


def test_metrics_text_exposition():
    m = SV.ServeMetrics()
    m.record_request(0.010, tier="top")
    m.record_request(0.500, tier="top", deadline_missed=True)
    m.record_batch("top", 2, 0.050, slots=4, cell="top/b4")
    m.record_failure("codec", 2)
    m.record_compile("top/b4")
    text = m.metrics_text()
    assert "# TYPE serve_requests_total counter" in text
    assert "serve_requests_total 2" in text
    assert 'serve_failures_total{reason="codec"} 2' in text
    assert 'serve_compiles_total{phase="warmup"} 1' in text
    assert 'serve_images_total{tier="top"} 2' in text
    assert "serve_device_wall_seconds_total 0.05" in text
    assert "# TYPE serve_request_latency_seconds histogram" in text
    assert 'serve_request_latency_seconds_bucket{le="+Inf"} 2' in text
    assert 'serve_request_latency_seconds_bucket{tier="top",le="+Inf"} 2' \
        in text
    assert "serve_request_latency_seconds_count 2" in text
    assert "serve_request_latency_seconds_sum 0.51" in text
    # cumulative le edges are monotone in count
    cums = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
            if line.startswith('serve_request_latency_seconds_bucket{le=')]
    assert cums == sorted(cums) and cums[-1] == 2


def test_metrics_writer_snapshots_and_final_write(tmp_path):
    m = SV.ServeMetrics()
    m.record_request(0.010)
    path = tmp_path / "metrics.prom"
    with MetricsWriter(m, str(path), interval_s=0.05) as w:
        deadline = 100
        while not path.exists() and deadline:
            threading.Event().wait(0.05)
            deadline -= 1
        assert path.exists(), "periodic snapshot never landed"
        m.record_request(0.020)
    # close() wrote a final snapshot including the late sample
    text = path.read_text()
    assert "serve_requests_total 2" in text
    assert not (tmp_path / "metrics.prom.tmp").exists()
