"""The JPEG linear map: roundtrips, explicit J/J~ tensors, linearity."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import dct as D
from repro.core import jpeg as J


@pytest.mark.parametrize("scaled", [True, False])
@pytest.mark.parametrize("shape", [(8, 8), (16, 24), (2, 3, 32, 16)])
def test_roundtrip(rng, scaled, shape):
    img = rng.normal(size=shape)
    co = J.jpeg_encode(jnp.asarray(img), scaled=scaled)
    back = J.jpeg_decode(co, scaled=scaled)
    assert np.allclose(back, img, atol=1e-5)


def test_dc_coefficient_is_block_mean(rng):
    img = rng.normal(size=(16, 16))
    co = J.jpeg_encode(jnp.asarray(img), scaled=True)
    means = np.asarray(img).reshape(2, 8, 2, 8).transpose(0, 2, 1, 3).mean((-1, -2))
    assert np.allclose(np.asarray(co)[..., 0], means, atol=1e-6)
    co_u = J.jpeg_encode(jnp.asarray(img), scaled=False)
    assert np.allclose(np.asarray(co_u)[..., 0], 8 * means, atol=1e-5)


def test_explicit_j_tensor_matches_encode(rng):
    x = rng.normal(size=(16, 16))
    jt = J.jpeg_tensor(16, 16)
    c_tensor = np.einsum("hwxyk,hw->xyk", jt, x)
    c_fn = np.asarray(J.jpeg_encode(jnp.asarray(x)))
    assert np.allclose(c_tensor, c_fn, atol=1e-6)


def test_explicit_ijpeg_tensor_inverts(rng):
    x = rng.normal(size=(16, 16))
    c = np.asarray(J.jpeg_encode(jnp.asarray(x)))
    ijt = J.ijpeg_tensor(16, 16)
    assert np.allclose(np.einsum("xykhw,xyk->hw", ijt, c), x, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**32 - 1), st.floats(-3, 3), st.floats(-3, 3))
def test_linearity_property(seed, a, b):
    """J(aF + bG) == a J(F) + b J(G) — the foundation of the whole paper."""
    r = np.random.default_rng(seed)
    f, g = r.normal(size=(2, 16, 16))
    lhs = J.jpeg_encode(jnp.asarray(a * f + b * g))
    rhs = a * J.jpeg_encode(jnp.asarray(f)) + b * J.jpeg_encode(jnp.asarray(g))
    assert np.allclose(lhs, rhs, atol=1e-4)


def test_lossy_roundtrip_reduces_energy(rng):
    img = rng.normal(size=(32, 32))
    out = J.jpeg_round_trip_lossy(jnp.asarray(img), quality=10)
    # quantization must change the image but keep it bounded
    assert not np.allclose(out, img, atol=1e-3)
    assert np.abs(np.asarray(out)).max() < 10 * np.abs(img).max() + 1


def test_block_unblock_inverse(rng):
    img = rng.normal(size=(3, 24, 16))
    assert np.allclose(J.unblock_image(J.block_image(jnp.asarray(img))), img)
