"""Plan-grid capture engine (``repro.serving.grid``).

Contracts:

* **bucket math is aphrodite-equivalent** — capture schedule 1, 2, 4,
  multiples of 8; a batch runs in the smallest covering bucket
  (1→1, 3→4, 9→16, 17→24 …), and the scheduler's full batch size always
  has a cell;
* **cells are exact** — a bucket cell's first ``n`` logits match the
  compiled plan applied to the unpadded batch (zero pad rows are
  row-independent), and a grid rebuilt from a restored ladder manifest
  produces bit-identical outputs per cell;
* **donation is safe** — the captured executable consumes its input
  buffer (enforced backends delete it; reuse raises) while the pinned
  host staging buffer stays reusable across calls;
* **warmup closes the shape set** — after the grid sweep, steady-state
  serving performs zero JIT compiles (``compiles_post_warmup == 0``)
  and partial batches pad only to the covering bucket
  (``padding_fraction`` in the report);
* **QoS estimates key per cell** — a bucket-1 trickle is not judged by
  bucket-8 latency under deadline pressure.
"""
import threading

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import dispatch as DSP
from repro.core import jpeg as J
from repro.core import plan as PL
from repro.core import resnet as R
from repro import serving as SV
from repro.serving.qos import QosPolicy, TierSelector

EXECUTOR = None if jax.default_backend() == "tpu" else "gemm"


@pytest.fixture(scope="module")
def setup():
    spec = R.ResNetSpec(widths=(6, 8), num_classes=10)
    params, state = R.init_resnet(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 3, 16, 16)) * 0.5
    coef = jnp.moveaxis(J.jpeg_encode(x, quality=spec.quality, scaled=True),
                        1, 3)
    plan = PL.build_plan(params, state, spec,
                         dispatch=DSP.DispatchConfig(path="reference"))
    return spec, coef, plan


# --------------------------------------------------------------------------
# Bucket math
# --------------------------------------------------------------------------


def test_batch_buckets_schedule():
    assert SV.batch_buckets(1) == (1,)
    assert SV.batch_buckets(2) == (1, 2)
    assert SV.batch_buckets(4) == (1, 2, 4)
    assert SV.batch_buckets(8) == (1, 2, 4, 8)
    assert SV.batch_buckets(24) == (1, 2, 4, 8, 16, 24)
    assert SV.batch_buckets(64) == (1, 2, 4, 8, 16, 24, 32, 40, 48, 56, 64)
    # a max_batch off the schedule is always captured itself
    assert SV.batch_buckets(6) == (1, 2, 4, 6)
    assert SV.batch_buckets(12) == (1, 2, 4, 8, 12)
    with pytest.raises(ValueError):
        SV.batch_buckets(0)


@pytest.mark.parametrize("n,want", [
    # the aphrodite _get_graph_batch_size equivalence table
    (1, 1), (2, 2), (3, 4), (4, 4), (5, 8), (8, 8),
    (9, 16), (16, 16), (17, 24), (24, 24), (25, 32),
])
def test_bucket_for_covering(n, want):
    assert SV.bucket_for(n, SV.batch_buckets(32)) == want


def test_bucket_for_rejects_uncovered():
    with pytest.raises(ValueError):
        SV.bucket_for(9, SV.batch_buckets(8))
    with pytest.raises(ValueError):
        SV.bucket_for(0, SV.batch_buckets(8))


def test_validate_and_cover_buckets():
    with pytest.raises(ValueError):
        SV.validate_buckets(())
    with pytest.raises(ValueError):
        SV.validate_buckets((2, 2))
    with pytest.raises(ValueError):
        SV.validate_buckets((4, 2))
    with pytest.raises(ValueError):
        SV.validate_buckets((0, 2))
    assert SV.cover_buckets(None, 12) == SV.batch_buckets(12)
    # explicit lists are clipped to the batch and always cover it
    assert SV.cover_buckets((1, 2, 4, 8, 16), 8) == (1, 2, 4, 8)
    assert SV.cover_buckets((1, 2), 6) == (1, 2, 6)
    assert SV.cover_buckets((8,), 8) == (8,)


# --------------------------------------------------------------------------
# Cell exactness + manifest round trip
# --------------------------------------------------------------------------


def test_cell_matches_unpadded_compiled_plan(setup):
    """The covering cell's first n logits == apply_compiled on the
    unpadded batch (zero pad rows are row-independent)."""
    spec, coef, plan = setup
    ladder = SV.build_ladder(plan, caps=(None, 16))
    g = SV.PlanGrid(ladder, batch=8, grid=tuple(coef.shape[1:3]),
                    channels=int(coef.shape[3]), executor=EXECUTOR)
    assert g.buckets == (1, 2, 4, 8)
    for tier_ix in (0, 1):
        cp = ladder.tiers[tier_ix].compiled
        col = g.columns[tier_ix]
        for n in (1, 3, 5, 8):
            rows = np.asarray(coef[:n], np.float32)
            want = np.asarray(PL.apply_compiled(cp, jnp.asarray(rows),
                                                executor=EXECUTOR))
            got = np.asarray(col.coef_fn(rows))
            assert got.shape[0] == g.bucket_for(n)
            np.testing.assert_allclose(got[:n], want, atol=1e-5)


def test_grid_manifest_roundtrip_bit_exact(setup, tmp_path):
    """Ladder manifest persists the capture buckets; a grid rebuilt from
    the restored ladder serves bit-identical logits per cell."""
    spec, coef, plan = setup
    ladder = SV.build_ladder(plan, caps=(None, 16), buckets=(1, 2, 4))
    d = str(tmp_path / "plan")
    SV.save_ladder(ladder, d)
    restored = SV.load_ladder(d)
    assert restored.buckets == (1, 2, 4)
    kw = dict(batch=4, grid=tuple(coef.shape[1:3]),
              channels=int(coef.shape[3]), executor=EXECUTOR)
    g0 = SV.PlanGrid(ladder, **kw)
    g1 = SV.PlanGrid(restored, **kw)
    assert g0.buckets == g1.buckets == (1, 2, 4)
    for tier_ix in range(len(ladder.tiers)):
        for n in (1, 3, 4):
            rows = np.asarray(coef[:n], np.float32)
            a = np.asarray(g0.columns[tier_ix].coef_fn(rows))
            b = np.asarray(g1.columns[tier_ix].coef_fn(rows))
            assert np.array_equal(a, b)


def test_captured_entry_rejects_foreign_shape(setup):
    """A captured executable is pinned: a different shape raises instead
    of silently retracing."""
    spec, coef, plan = setup
    cp = PL.compile_plan(plan)
    fn = PL.capture_compiled(cp, (2, *coef.shape[1:]), executor=EXECUTOR)
    np.asarray(fn(jnp.asarray(coef[:2])))
    with pytest.raises(ValueError, match="pinned"):
        fn(jnp.asarray(coef[:3]))


# --------------------------------------------------------------------------
# Donation safety + pinned staging reuse
# --------------------------------------------------------------------------


def test_donated_input_not_reusable_after_call(setup):
    spec, coef, plan = setup
    cp = PL.compile_plan(plan)
    fn = PL.capture_compiled(cp, (2, *coef.shape[1:]), executor=EXECUTOR,
                             donate=True)
    x = jnp.array(coef[:2])
    out = np.asarray(fn(x))
    assert np.isfinite(out).all()
    if not x.is_deleted():
        pytest.skip("backend does not enforce buffer donation")
    with pytest.raises(RuntimeError):
        fn(x)  # the donated buffer is gone — reuse must fail loudly


def test_cell_staging_buffer_survives_donation(setup):
    """GridCell stages into a pinned host buffer and copies to device, so
    repeated calls through the same cell never trip over the donation —
    and different payloads through the same staging buffer stay exact."""
    spec, coef, plan = setup
    ladder = SV.build_ladder(plan, caps=(None,))
    g = SV.PlanGrid(ladder, batch=4, grid=tuple(coef.shape[1:3]),
                    channels=int(coef.shape[3]), executor=EXECUTOR)
    col = g.columns[0]
    cp = ladder.tiers[0].compiled
    for i in range(4):  # same cell, same staging buffer, fresh rows
        rows = np.asarray(coef[i:i + 1], np.float32)
        want = np.asarray(PL.apply_compiled(cp, jnp.asarray(rows),
                                            executor=EXECUTOR))
        got = np.asarray(col.coef_fn(rows))
        np.testing.assert_allclose(got[:1], want, atol=1e-5)
    cell = col.cells[("coefficients", 1)]
    assert cell.hits == 4
    # one staging buffer per distinct shape, not per call
    assert len(g.pool) == 1


def test_cell_rejects_oversized_or_foreign_rows(setup):
    spec, coef, plan = setup
    ladder = SV.build_ladder(plan, caps=(None,))
    g = SV.PlanGrid(ladder, batch=2, grid=tuple(coef.shape[1:3]),
                    channels=int(coef.shape[3]), executor=EXECUTOR)
    cell = g.columns[0].cell("coefficients", 2, coef.shape[1:])
    with pytest.raises(ValueError, match="serves shape"):
        cell(np.asarray(coef[:3], np.float32))  # over the bucket
    with pytest.raises(ValueError, match="serves shape"):
        cell(np.zeros((1, 1, 1, 3, 64), np.float32))  # wrong item shape


# --------------------------------------------------------------------------
# QoS: per-cell latency estimates
# --------------------------------------------------------------------------


def test_selector_keys_latency_per_bucket():
    """Deadline pressure is judged against the latency of the cell the
    batch will actually run in: a cheap bucket-1 trickle must not be
    degraded because bucket-8 batches are slow (and vice versa)."""
    sel = TierSelector(2, QosPolicy(hysteresis=1))
    sel.observe(0, 0.5, bucket=8)    # full batches: 500ms
    sel.observe(0, 0.01, bucket=1)   # singles: 10ms
    assert sel.est_latency(0, 1) == pytest.approx(0.01)
    assert sel.est_latency(0, 8) == pytest.approx(0.5)
    # 100ms of slack: fine for a single, hopeless for a full batch
    assert sel.select(pending=2, batch=8, head_slack_s=0.1, bucket=1) == 0
    assert sel.select(pending=2, batch=8, head_slack_s=0.1, bucket=8) == 1


def test_selector_bucket_estimates_fall_back_sensibly():
    sel = TierSelector(3, QosPolicy(hysteresis=1))
    sel.observe(1, 0.2, bucket=4)
    # same tier, nearest bucket
    assert sel.est_latency(1, 8) == pytest.approx(0.2)
    # neighbour tier when the tier has no observations at all
    assert sel.est_latency(0, 4) == pytest.approx(0.2)
    # wildcard read prefers the largest observed bucket (conservative)
    sel.observe(1, 0.05, bucket=1)
    assert sel.est_latency(1) == pytest.approx(0.2)
    # pre-grid wildcard observations still resolve exactly
    sel2 = TierSelector(2, QosPolicy(hysteresis=1))
    sel2.observe(0, 0.3)
    assert sel2.est_latency(0) == pytest.approx(0.3)
    assert sel2.est_latency(0, 8) == pytest.approx(0.3)


# --------------------------------------------------------------------------
# Scheduler integration: zero post-warmup compiles, bucketed padding
# --------------------------------------------------------------------------


def _sched(plan, coef, **kw):
    ladder = kw.pop("ladder", None) or SV.build_ladder(plan, caps=(None, 16))
    kw.setdefault("batch", 4)
    kw.setdefault("grid", tuple(coef.shape[1:3]))
    kw.setdefault("channels", int(coef.shape[3]))
    return SV.BandElasticScheduler(ladder, **kw)


def test_scheduler_zero_compiles_after_warmup(setup):
    """The warmup sweep closes the compiled-shape set: a mixed-occupancy
    stream (singles, partial batches, full batches) performs zero JIT
    compiles, every batch lands in its covering bucket's cell, and the
    padding waste is visible in the report."""
    spec, coef, plan = setup
    with _sched(plan, coef) as s:
        s.warmup(kinds=("coefficients",))
        warm = s.metrics.report()
        # 2 distinct tier columns x buckets (1, 2, 4), coefficients only
        assert s.buckets == (1, 2, 4)
        assert warm["compiles_total"] == 6
        assert warm["compiles_post_warmup"] == 0

        reqs = []
        for _ in range(3):  # trickle: one at a time, fully drained
            r = s.submit(np.asarray(coef[0]))
            r.result(timeout=60)
            reqs.append(r)
        with s._lock:  # a 3-deep group dispatched as one take → bucket 4
            for i in range(3):
                reqs.append(SV.ServeRequest(9000 + i, "coefficients",
                                            np.asarray(coef[i]), None))
                s._queues["coefficients"].append(reqs[-1])
            s._work.notify_all()
        for i in range(8):  # saturating tail
            reqs.append(s.submit(np.asarray(coef[i % coef.shape[0]])))
        s.drain(timeout=120)
    assert all(r.done() for r in reqs)
    rep = s.metrics.report()
    assert rep["compiles_total"] == 6          # nothing new compiled
    assert rep["compiles_post_warmup"] == 0
    assert "post_warmup_compiles" not in rep
    hits = rep["grid_cell_hits"]
    assert hits and all("/coefficients/b" in k for k in hits)
    assert sum(hits.values()) == sum(
        t["batches"] for t in rep["per_tier"].values())
    # the trickle ran in bucket 1 (no pad-to-max), the group padded 3→4
    assert any(k.endswith("/b1") for k in hits)
    assert rep["padding_fraction"] is not None
    assert 0.0 <= rep["padding_fraction"] < 1.0


def test_scheduler_lazy_compile_is_counted_post_warmup(setup):
    """An unwarmed kind that compiles mid-traffic is not silent: the
    compile accounting reports it (this is exactly what the CI
    zero-compile assertion would catch)."""
    spec, coef, plan = setup
    with _sched(plan, coef, batch=2) as s:
        s.warmup(kinds=())   # declare warm without compiling anything
        s.submit(np.asarray(coef[0])).result(timeout=60)
    rep = s.metrics.report()
    assert rep["compiles_total"] == 1
    assert rep["compiles_post_warmup"] == 1
    assert rep["post_warmup_compiles"] == ["top/coefficients/b1"]


def test_scheduler_fixed_bucket_reproduces_pad_to_max(setup):
    """buckets=(batch,) is the pre-grid behaviour: every batch pads to
    the full slot count."""
    spec, coef, plan = setup
    with _sched(plan, coef, buckets=(4,)) as s:
        assert s.buckets == (4,)
        s.submit(np.asarray(coef[0])).result(timeout=60)
    rep = s.metrics.report()
    assert rep["padding_fraction"] == pytest.approx(0.75)
    assert list(rep["grid_cell_hits"]) == ["top/coefficients/b4"]


def test_scheduler_bytes_grid_cells(setup):
    """bytes traffic routes through packed cells of the covering bucket
    and stays compile-free after a bytes warmup."""
    from repro.codec import encode_pixels
    from repro.core import dct as dctlib

    spec, coef, plan = setup
    rng = np.random.default_rng(3)
    qt = np.rint(dctlib.quantization_table(
        75, dc_is_mean=False)).astype(np.int64)
    datas = [encode_pixels(
        np.clip(rng.normal(0, 0.3, (3, 16, 16)), -1.0, 127.0 / 128.0),
        qtable=qt) for _ in range(5)]
    with _sched(plan, coef) as s:
        s.warmup(kinds=("bytes",))
        compiled_at_warmup = s.metrics.report()["compiles_total"]
        reqs = [s.submit(d, kind="bytes") for d in datas]
        outs = [r.result(timeout=60) for r in reqs]
    assert all(np.isfinite(o).all() for o in outs)
    rep = s.metrics.report()
    assert rep["compiles_total"] == compiled_at_warmup
    assert rep["compiles_post_warmup"] == 0
    assert all("/bytes/b" in k for k in rep["grid_cell_hits"])


def test_grid_warmup_and_summary(setup):
    spec, coef, plan = setup
    ladder = SV.build_ladder(plan, caps=(None, 16))
    g = SV.PlanGrid(ladder, batch=4, grid=tuple(coef.shape[1:3]),
                    channels=int(coef.shape[3]), executor=EXECUTOR)
    g.warmup(kinds=("coefficients",))
    summ = g.summary()
    assert summ["buckets"] == [1, 2, 4]
    assert summ["distinct_columns"] == 2
    assert summ["cells"] == 6
    assert summ["host_staging_bytes"] > 0
    assert set(g.cell_hits()) == {
        f"{t}/coefficients/b{b}" for t in ("top", "b16") for b in (1, 2, 4)}
