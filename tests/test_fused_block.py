"""Compiled plan execution (``core.plan.compile_plan`` +
``kernels.fused_block`` + ``kernels.tiling``).

Contracts:

* **Fused-block parity sweep** — the compiled schedule matches the
  per-layer ``apply_plan`` walk through stride-1 and stride-2 blocks,
  projection and identity shortcuts, bands ∈ {32, 48, 64} and
  φ ∈ {8, 14}, on the reference (spatial-resident) and pallas
  (megakernel, interpreted) executors;
* the Pallas megakernel body agrees with its packed-operator XLA twin on
  arbitrary inputs (not just band-limited ones);
* **compiled-plan serialization** — save → ``CheckpointManager`` → load
  returns bit-identical logits and an identical schedule;
* factored plans (no materialised Ξ) compile to an all-fallback schedule
  that still matches, and the VMEM budget demotes oversized blocks only
  on the pallas path;
* ``tiling.pick_tile`` sizes row tiles from ``n`` (sublane-aligned,
  balanced) instead of padding small inputs up to the max tile.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import dispatch as DSP
from repro.core import jpeg as J
from repro.core import plan as PL
from repro.core import resnet as R
from repro.kernels import tiling
from repro.kernels.fused_block import fused_block_pallas, \
    fused_block_reference


@pytest.fixture(scope="module")
def setup():
    # widths force a stride-2 + projection block in stage 1; stage 0 is an
    # identity-shortcut stride-1 block.
    spec = R.ResNetSpec(widths=(6, 8), num_classes=10)
    params, state = R.init_resnet(jax.random.PRNGKey(0), spec)
    # randomise every BN so the folds the compiler re-lowers are non-trivial
    key = jax.random.PRNGKey(7)
    for name in params:
        if "_bn" in name or name.endswith("bn"):
            k1, k2, k3, k4, key = jax.random.split(key, 5)
            c = params[name]["gamma"].shape[0]
            params[name]["gamma"] = 1.0 + 0.2 * jax.random.normal(k1, (c,))
            params[name]["beta"] = 0.1 * jax.random.normal(k2, (c,))
            state[name]["mean"] = 0.1 * jax.random.normal(k3, (c,))
            state[name]["var"] = 1.0 + 0.3 * jax.random.uniform(k4, (c,))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 16, 16)) * 0.5
    coef = jnp.moveaxis(J.jpeg_encode(x, quality=spec.quality, scaled=True),
                        1, 3)
    return spec, params, state, coef


@pytest.mark.parametrize("phi", [8, 14])
@pytest.mark.parametrize("bands", [32, 48, 64])
def test_compiled_matches_plan_reference(setup, bands, phi):
    """Spatial-resident fused blocks ≡ the per-layer plan walk, through
    strided/projection and identity blocks, across bands and φ."""
    spec, params, state, coef = setup
    cfg = DSP.DispatchConfig(path="reference", bands=bands)
    plan = PL.build_plan(params, state, spec, phi=phi, dispatch=cfg)
    cp = PL.compile_plan(plan)
    assert cp.meta["fused"] == ["s0b0", "s1b0"]
    strided = cp.blocks[1]
    assert strided.conv1.stride == 2 and strided.proj is not None
    ident = cp.blocks[0]
    assert ident.conv1.stride == 1 and ident.proj is None
    ref = np.asarray(PL.apply_plan(plan, coef))
    got = np.asarray(PL.apply_compiled(cp, coef))
    np.testing.assert_allclose(got, ref, atol=2e-4)
    assert (got.argmax(-1) == ref.argmax(-1)).all()


@pytest.mark.parametrize("bands", [32, 64])
def test_compiled_matches_plan_pallas_interpret(setup, bands):
    """The megakernel (Pallas interpreter) executes the same schedule."""
    spec, params, state, coef = setup
    cfg = DSP.DispatchConfig(path="pallas", bands=bands, interpret=True)
    plan = PL.build_plan(params, state, spec, dispatch=cfg)
    cp = PL.compile_plan(plan)
    assert cp.meta["path"] == "pallas" and cp.meta["fused"]
    ref = np.asarray(PL.apply_plan(plan, coef))
    got = np.asarray(PL.apply_compiled(cp, coef))
    np.testing.assert_allclose(got, ref, atol=2e-4)
    assert (got.argmax(-1) == ref.argmax(-1)).all()


def test_megakernel_matches_packed_xla_twin(setup):
    """fused_block_pallas ≡ fused_block_reference on arbitrary packed
    inputs (both shortcut kinds), not only band-limited ones."""
    spec, params, state, coef = setup
    cfg = DSP.DispatchConfig(path="pallas", bands=48, interpret=True)
    cp = PL.compile_plan(PL.build_plan(params, state, spec, dispatch=cfg))
    key = jax.random.PRNGKey(3)
    grid = {"s0b0": 2, "s1b0": 2}
    for blk in cp.blocks:
        assert blk.kind == "fused"
        bh = grid[blk.name]
        x = jax.random.normal(key, (3, bh, bh, blk.cin * blk.w_in))
        want = fused_block_reference(x, blk.conv1, blk.asm_mid, blk.conv2,
                                     blk.asm_out, blk.proj)
        got = fused_block_pallas(x, blk.conv1, blk.asm_mid, blk.conv2,
                                 blk.asm_out, blk.proj, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, err_msg=blk.name)


def test_compiled_roundtrip_bit_identical(setup, tmp_path):
    """save_compiled_plan → CheckpointManager → load_compiled_plan serves
    bit-identical logits with an identical schedule."""
    spec, params, state, coef = setup
    cfg = DSP.DispatchConfig(path="reference", bands=40)
    cp = PL.compile_plan(PL.build_plan(params, state, spec, dispatch=cfg))
    before = np.asarray(PL.apply_compiled(cp, coef))
    PL.save_compiled_plan(cp, str(tmp_path))
    restored = PL.load_compiled_plan(str(tmp_path))
    assert restored.spec == cp.spec
    assert restored.bands == cp.bands
    assert restored.meta == cp.meta
    assert [b.kind for b in restored.blocks] == [b.kind for b in cp.blocks]
    after = np.asarray(PL.apply_compiled(restored, coef))
    np.testing.assert_array_equal(before, after)


def test_load_compiled_rejects_foreign_checkpoint(tmp_path):
    from repro.checkpoint import CheckpointManager

    CheckpointManager(str(tmp_path)).save(0, {"w": np.ones(3)})
    with pytest.raises(ValueError, match="compiled plan"):
        PL.load_compiled_plan(str(tmp_path))


def test_factored_plan_compiles_to_fallback(setup):
    """No materialised Ξ → every step stays on the per-layer walk, and the
    compiled schedule still matches the plan."""
    spec, params, state, coef = setup
    cfg = DSP.DispatchConfig(path="factored", bands=32)
    plan = PL.build_plan(params, state, spec, dispatch=cfg)
    cp = PL.compile_plan(plan)
    assert cp.meta["fused"] == []
    assert set(cp.meta["layers"]) == {"stem", "s0b0", "s1b0"}
    np.testing.assert_allclose(np.asarray(PL.apply_compiled(cp, coef)),
                               np.asarray(PL.apply_plan(plan, coef)),
                               atol=1e-5)


def test_vmem_budget_gates_pallas_only(setup):
    """An undersized budget demotes pallas blocks to the per-layer walk
    (the megakernel's operands must fit VMEM) but never reference blocks
    (the XLA executor has no such limit)."""
    spec, params, state, coef = setup
    pcfg = DSP.DispatchConfig(path="pallas", bands=32, interpret=True)
    plan = PL.build_plan(params, state, spec, dispatch=pcfg)
    cp = PL.compile_plan(plan, vmem_budget=1)
    assert cp.meta["fused"] == []
    assert all("vmem" in reason for name, reason in cp.meta["layers"].items()
               if name != "stem")
    np.testing.assert_allclose(np.asarray(PL.apply_compiled(cp, coef)),
                               np.asarray(PL.apply_plan(plan, coef)),
                               atol=1e-4)
    rcfg = DSP.DispatchConfig(path="reference", bands=32)
    cp_ref = PL.compile_plan(PL.build_plan(params, state, spec,
                                           dispatch=rcfg), vmem_budget=1)
    assert cp_ref.meta["fused"] == ["s0b0", "s1b0"]


def test_compile_for_inference_wrapper(setup):
    spec, params, state, coef = setup
    cfg = DSP.DispatchConfig(path="reference", bands=48)
    cp = R.compile_for_inference(params, state, spec, dispatch=cfg)
    plan = PL.build_plan(params, state, spec, dispatch=cfg)
    np.testing.assert_allclose(np.asarray(cp(coef)),
                               np.asarray(PL.apply_plan(plan, coef)),
                               atol=2e-4)


def test_pick_tile_sizes_from_input():
    """Tiles are balanced, sublane-aligned, and never waste >1 sublane of
    rows — a single-image request no longer pads up to the max tile."""
    for n in (1, 5, 16, 128, 1000, 1024, 1040, 5000):
        tile = tiling.pick_tile(n, 1024)
        assert tile <= 1024 and tile % tiling.SUBLANE == 0 or tile == n
        num = -(-n // tile)
        waste = num * tile - n
        assert waste < tiling.SUBLANE + tile / 8, (n, tile, waste)
    assert tiling.pick_tile(16, 1024) == 16      # small input: own tile
    assert tiling.pick_tile(1040, 1024) == 520   # balanced split, no pad
    with pytest.raises(ValueError):
        tiling.pick_tile(0, 1024)


def test_asm_relu_kernel_small_input_no_max_tile_pad():
    """The asm_relu kernel's tile now follows the input size."""
    from repro.core import asm as asmlib
    from repro.kernels import ops as kops

    coef = jax.random.normal(jax.random.PRNGKey(2), (3, 2, 2, 4, 64)) * 0.4
    want = asmlib.asm_relu(coef, 8)
    got = kops.asm_relu(coef, 8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
