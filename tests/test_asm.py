"""Approximated Spatial Masking: exactness, the paper's Fig. 4a ordering."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import asm as A
from repro.core import dct as D


def _rand_blocks(rng, n=64):
    """Paper §5.3 protocol: random 4x4 blocks box-upscaled to 8x8."""
    small = rng.uniform(-1, 1, size=(n, 4, 4))
    big = np.kron(small, np.ones((2, 2)))
    return D.dct2(big).reshape(n, 64)[:, D.zigzag_permutation()]


def test_asm_exact_at_full_bands(rng):
    coef = jnp.asarray(_rand_blocks(rng))
    out = A.asm_relu(coef, phi=A.EXACT_PHI)
    oracle = A.spatial_relu_oracle(coef)
    assert np.allclose(out, oracle, atol=1e-10)


def test_asm_beats_apx_at_every_phi(rng):
    """Paper Fig. 4a: ASM RMSE < APX RMSE for phi = 1..14."""
    coef = jnp.asarray(_rand_blocks(rng, 256))
    oracle = A.spatial_relu_oracle(coef)
    for phi in range(1, 15):
        e_asm = float(jnp.sqrt(jnp.mean((A.asm_relu(coef, phi) - oracle) ** 2)))
        e_apx = float(jnp.sqrt(jnp.mean((A.apx_relu(coef, phi) - oracle) ** 2)))
        assert e_asm <= e_apx + 1e-9, (phi, e_asm, e_apx)


def test_asm_error_decreases_with_phi(rng):
    coef = jnp.asarray(_rand_blocks(rng, 256))
    oracle = A.spatial_relu_oracle(coef)
    errs = [float(jnp.mean((A.asm_relu(coef, phi) - oracle) ** 2))
            for phi in (2, 6, 10, 14)]
    assert errs[0] >= errs[1] >= errs[2] >= errs[3]
    assert errs[-1] < 1e-12


def test_asm_preserves_values_where_mask_correct(rng):
    """The paper's key claim (Fig. 1): ASM errors live only in the mask."""
    coef = jnp.asarray(_rand_blocks(rng, 32))
    recon = jnp.asarray(D.reconstruction_matrix())
    spatial = coef @ recon  # exact pixels
    phi = 6
    approx_mask = np.asarray(A.nonnegative_mask(coef, phi))
    true_mask = np.asarray(spatial > 0)
    out_spatial = np.asarray(A.asm_relu(coef, phi) @ recon)
    relu_spatial = np.maximum(np.asarray(spatial), 0.0)
    agree = approx_mask == true_mask
    # Wherever the approximate mask is right, the value is *exact*.
    assert np.allclose(out_spatial[agree], relu_spatial[agree], atol=1e-6)


def test_piecewise_general_matches_relu(rng):
    coef = jnp.asarray(_rand_blocks(rng))
    a = A.asm_piecewise(coef, A.RELU, phi=14)
    b = A.asm_relu(coef, phi=14)
    assert np.allclose(a, b, atol=1e-8)


def test_piecewise_leaky_relu(rng):
    coef = jnp.asarray(_rand_blocks(rng))
    recon = jnp.asarray(D.reconstruction_matrix())
    out = A.asm_piecewise(coef, A.LEAKY_RELU, phi=14) @ recon
    spatial = np.asarray(coef @ recon)
    expect = np.where(spatial > 0, spatial, 0.01 * spatial)
    assert np.allclose(out, expect, atol=1e-6)


def test_scaled_convention_via_qtable(rng):
    """Eq. 20: quantization diagonals folded into the ASM matrices."""
    q = D.quantization_table(50)
    coef_dct = jnp.asarray(_rand_blocks(rng))
    coef_jpeg = coef_dct / jnp.asarray(q)
    out_jpeg = A.asm_relu(coef_jpeg, phi=14, qtable=q)
    out_dct = A.asm_relu(coef_dct, phi=14)
    np.testing.assert_allclose(out_jpeg * jnp.asarray(q), out_dct,
                               atol=1e-5, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(1, 14))
def test_asm_output_energy_bounded(seed, phi):
    """ReLU is a projection: masked output never exceeds input energy
    (holds for ASM because masking zeroes pixels of the exact values)."""
    r = np.random.default_rng(seed)
    coef = jnp.asarray(_rand_blocks(r, 8))
    out = A.asm_relu(coef, phi)
    in_e = float(jnp.sum(coef * coef))
    out_e = float(jnp.sum(out * out))
    assert out_e <= in_e + 1e-6
