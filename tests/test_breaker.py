"""Circuit-breaker unit contracts (``repro.serving.breaker``).

Driven entirely on a fake clock so every timer transition is
deterministic: open on consecutive failures or rolling failure rate,
refuse while open, half-open when the timer expires, close on probe
successes, re-open on a probe failure.
"""
import pytest

from repro.serving.breaker import BreakerPolicy, CircuitBreaker


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _breaker(clock, events=None, **kw):
    policy = BreakerPolicy(**kw)
    on_transition = None
    if events is not None:
        on_transition = lambda f, t, r: events.append((f, t, r))  # noqa: E731
    return CircuitBreaker(policy, clock=clock, on_transition=on_transition)


def test_opens_on_consecutive_failures():
    clock, events = FakeClock(), []
    b = _breaker(clock, events, max_consecutive=3, min_samples=100)
    b.record_failure("executor")
    b.record_failure("executor")
    assert b.state == "closed" and b.allow()
    b.record_failure("executor")
    assert b.state == "open"
    assert not b.allow()
    assert events == [("closed", "open", "executor")]


def test_success_resets_consecutive_streak():
    clock = FakeClock()
    b = _breaker(clock, max_consecutive=3, min_samples=100)
    for _ in range(5):
        b.record_failure("executor")
        b.record_failure("executor")
        b.record_success()  # streak broken before the threshold
    assert b.state == "closed"


def test_opens_on_rolling_failure_rate():
    clock = FakeClock()
    b = _breaker(clock, window=10, failure_rate=0.5, min_samples=8,
                 max_consecutive=1000)
    # alternate so the consecutive streak never fires; the window rate does
    outcomes = [True, False] * 3 + [True, False, True]
    for fail in outcomes[:-1]:
        b.record_failure("x") if fail else b.record_success()
        assert b.state == "closed"
    b.record_failure("x")  # 5 failures / 9 samples >= 0.5, samples >= 8
    assert b.state == "open"


def test_rate_needs_min_samples():
    clock = FakeClock()
    b = _breaker(clock, window=10, failure_rate=0.5, min_samples=8,
                 max_consecutive=1000)
    for _ in range(7):  # 100% failure but under min_samples... no, 7 < 8
        b.record_failure("x")
    # max_consecutive=1000 keeps the streak path out; 7 samples < 8
    assert b.state == "closed"


def test_half_open_after_timer_and_close_on_probes():
    clock, events = FakeClock(), []
    b = _breaker(clock, events, max_consecutive=2, min_samples=100,
                 open_s=1.0, half_open_successes=2)
    b.record_failure("executor")
    b.record_failure("executor")
    assert b.state == "open"
    clock.advance(0.5)
    assert not b.allow()          # timer not expired
    clock.advance(0.6)
    assert b.allow()              # flips to half_open, admits the probe
    assert b.state == "half_open"
    b.record_success()
    assert b.state == "half_open"  # one probe success is not enough
    b.record_success()
    assert b.state == "closed"
    assert [(f, t) for f, t, _ in events] == [
        ("closed", "open"), ("open", "half_open"), ("half_open", "closed")]


def test_half_open_failure_reopens():
    clock = FakeClock()
    b = _breaker(clock, max_consecutive=2, min_samples=100, open_s=1.0)
    b.record_failure("executor")
    b.record_failure("executor")
    clock.advance(1.0)
    assert b.allow() and b.state == "half_open"
    b.record_failure("executor")
    assert b.state == "open"
    assert not b.allow()
    clock.advance(1.0)
    assert b.allow() and b.state == "half_open"  # timer restarts each open


def test_close_clears_window():
    clock = FakeClock()
    b = _breaker(clock, window=8, failure_rate=0.5, min_samples=4,
                 max_consecutive=2, open_s=1.0, half_open_successes=1)
    b.record_failure("x")
    b.record_failure("x")
    clock.advance(1.0)
    assert b.allow()
    b.record_success()
    assert b.state == "closed"
    snap = b.snapshot()
    # the old failure window must not instantly re-trip the fresh close
    assert snap["window_samples"] == 0
    assert snap["consecutive_failures"] == 0


def test_snapshot_fields():
    clock = FakeClock()
    b = _breaker(clock, max_consecutive=5, min_samples=2, window=4)
    b.record_failure("ingest")
    b.record_success()
    snap = b.snapshot()
    assert snap["state"] == "closed"
    assert snap["window_samples"] == 2
    assert snap["window_failure_rate"] == pytest.approx(0.5)
    assert snap["consecutive_failures"] == 0
    assert snap["last_failure_reason"] == "ingest"


def test_thread_safety_smoke():
    import threading

    clock = FakeClock()
    b = _breaker(clock, window=32, max_consecutive=10_000,
                 min_samples=10_000)

    def pound(fail: bool):
        for _ in range(500):
            b.record_failure("x") if fail else b.record_success()
            b.allow()
            b.snapshot()

    ts = [threading.Thread(target=pound, args=(i % 2 == 0,))
          for i in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert b.state == "closed"
    assert b.snapshot()["window_samples"] == 32
