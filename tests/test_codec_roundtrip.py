"""Encoder → decoder round-trip property tests (bit-exact entropy coding)
and the exactness of the normalization stage's linear maps."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import dct as dctlib
from repro.core import jpeg as J
from repro.codec import bitstream as bs
from repro.codec import encode as enc
from repro.codec import normalize as nm

from _hypothesis_compat import given, settings, st


def _random_coefficients(rng, by, bx, density=0.3, lim=1023):
    c = np.zeros((by, bx, dctlib.NFREQ), np.int32)
    mask = rng.random((by, bx, dctlib.NFREQ)) < density
    c[mask] = rng.integers(-lim, lim + 1, int(mask.sum()))
    c[..., 0] = rng.integers(-1024, 1017, (by, bx))
    return c


@settings(max_examples=12)
@given(st.integers(1, 5), st.integers(1, 5), st.integers(0, 10_000),
       st.booleans())
def test_roundtrip_single_component(by, bx, seed, use_restart):
    rng = np.random.default_rng(seed)
    c = _random_coefficients(rng, by, bx,
                             density=float(rng.uniform(0.02, 0.6)))
    q = np.rint(dctlib.quantization_table(50)).astype(np.int64)
    ri = int(rng.integers(1, by * bx + 1)) if use_restart else 0
    data = enc.encode_baseline([c], [q], restart_interval=ri)
    dec = bs.decode_jpeg(data)
    assert np.array_equal(dec.coefficients[0], c)
    assert np.array_equal(dec.qtables[dec.components[0].tq], q)
    assert dec.restart_interval == ri


@settings(max_examples=8)
@given(st.integers(0, 10_000), st.booleans())
def test_roundtrip_three_components(seed, subsampled):
    rng = np.random.default_rng(seed)
    if subsampled:
        comps = [_random_coefficients(rng, 4, 4, 0.25)] + \
                [_random_coefficients(rng, 2, 2, 0.25) for _ in range(2)]
        sampling = [(2, 2), (1, 1), (1, 1)]
    else:
        comps = [_random_coefficients(rng, 3, 2, 0.25) for _ in range(3)]
        sampling = [(1, 1)] * 3
    qs = [np.rint(dctlib.quantization_table(q)).astype(np.int64)
          for q in (50, 75, 75)]
    data = enc.encode_baseline(comps, qs, sampling=sampling)
    dec = bs.decode_jpeg(data)
    for i in range(3):
        assert np.array_equal(dec.coefficients[i], comps[i]), i
        assert np.array_equal(dec.qtable(i), qs[i]), i
        assert (dec.components[i].h, dec.components[i].v) == sampling[i]


def test_roundtrip_16bit_qtable():
    rng = np.random.default_rng(7)
    c = _random_coefficients(rng, 2, 2, 0.3, lim=100)
    q = np.full(dctlib.NFREQ, 300, np.int64)  # needs 16-bit DQT precision
    data = enc.encode_baseline([c], [q])
    dec = bs.decode_jpeg(data)
    assert np.array_equal(dec.coefficients[0], c)
    assert np.array_equal(dec.qtable(0), q)


def test_roundtrip_extreme_runs():
    """ZRL chains, EOB-less blocks, all-zero blocks."""
    c = np.zeros((2, 2, dctlib.NFREQ), np.int32)
    c[0, 0, 63] = 5          # 62 zeros -> 3 ZRLs + run
    c[0, 1, :] = 0           # all-zero block (EOB immediately)
    c[1, 0, 1:] = 1          # dense block, no EOB
    c[1, 1, 0] = -1024       # extreme DC swing after 0
    q = np.rint(dctlib.quantization_table(50)).astype(np.int64)
    data = enc.encode_baseline([c], [q])
    assert np.array_equal(bs.decode_jpeg(data).coefficients[0], c)


def test_encoder_rejects_out_of_range():
    q = np.rint(dctlib.quantization_table(50)).astype(np.int64)
    c = np.zeros((1, 1, dctlib.NFREQ), np.int32)
    c[0, 0, 3] = 2000  # AC size category 11 — not codable in baseline
    with pytest.raises(ValueError):
        enc.encode_baseline([c], [q])
    c = np.zeros((1, 2, dctlib.NFREQ), np.int32)
    c[0, 0, 0], c[0, 1, 0] = -2000, 2000  # DC diff 4000 -> category 12
    with pytest.raises(ValueError):
        enc.encode_baseline([c], [q])


@settings(max_examples=6)
@given(st.integers(0, 10_000), st.sampled_from([(2, 2), (2, 1), (1, 2)]))
def test_upsample_matches_spatial_replication(seed, f):
    """Coefficient-domain chroma upsampling == decode, replicate pixels,
    re-encode — exactly (replication is linear, R is orthonormal)."""
    fy, fx = f
    rng = np.random.default_rng(seed)
    coef = rng.normal(size=(2, 3, dctlib.NFREQ))
    up = nm.upsample_coefficients(coef, fy, fx)
    spat = np.asarray(J.jpeg_decode(jnp.asarray(coef[None]), scaled=False))[0]
    rep = np.repeat(np.repeat(spat, fy, 0), fx, 1)
    ref = np.asarray(J.jpeg_encode(jnp.asarray(rep[None]), scaled=False))[0]
    assert np.abs(up - ref).max() < 1e-5


def test_rescale_is_the_exact_linear_map():
    rng = np.random.default_rng(3)
    v = rng.integers(-500, 500, (2, 2, dctlib.NFREQ))
    q_file = np.rint(dctlib.quantization_table(85, dc_is_mean=False))
    out = nm.rescale_component(v, q_file, quality=50)
    expect = (v * q_file / (128.0 * dctlib.quantization_table(50)))
    assert np.abs(out - expect).max() < 1e-6


def test_mixed_quality_normalizes_to_one_convention():
    """The same image encoded at different qualities lands near the same
    canonical coefficients after normalization (within quantization
    error) — the property that lets one plan serve mixed traffic."""
    rng = np.random.default_rng(11)
    img = np.clip(rng.normal(size=(32, 32)) * 0.3, -1, 127 / 128.0)
    exact = np.asarray(J.jpeg_encode(jnp.asarray(img[None]), quality=50,
                                     scaled=True))[0]
    for q in (35, 60, 90):
        qt = np.rint(dctlib.quantization_table(
            q, dc_is_mean=False)).astype(np.int64)
        data = enc.encode_pixels(img, qtable=qt)
        dec = bs.decode_jpeg(data)
        got = nm.normalize_image(dec, quality=50)[:, :, 0]
        # per-coefficient quantization error bound: half a file step,
        # mapped through the same linear rescale
        bound = 0.5 * qt / (128.0 * dctlib.quantization_table(50)) + 1e-6
        assert (np.abs(got - exact) <= bound).all(), q


def test_fit_grid_pad_and_crop():
    coef = np.arange(3 * 5 * 64, dtype=np.float32).reshape(3, 5, 64)
    padded = nm.fit_grid(coef, 4, 6)
    assert padded.shape == (4, 6, 64)
    assert np.array_equal(padded[:3, :5], coef)
    assert not padded[3].any() and not padded[:, 5].any()
    cropped = nm.fit_grid(coef, 2, 3)  # center crop
    assert np.array_equal(cropped, coef[0:2, 1:4])


def test_420_normalization_exact_in_dct_basis():
    """Regression: the canonical per-index rescale must come AFTER the
    chroma upsample (the upsample map mixes zigzag indices).  Ground
    truth: de-quantize chroma, IDCT to pixels, replicate 2×2, re-encode
    under the canonical convention."""
    rng = np.random.default_rng(17)
    y = _random_coefficients(rng, 4, 4, 0.2, lim=200)
    cb = _random_coefficients(rng, 2, 2, 0.2, lim=200)
    cr = _random_coefficients(rng, 2, 2, 0.2, lim=200)
    qt = np.rint(dctlib.quantization_table(
        70, dc_is_mean=False)).astype(np.int64)
    data = enc.encode_baseline([y, cb, cr], [qt] * 3,
                               sampling=[(2, 2), (1, 1), (1, 1)])
    got = nm.normalize_image(bs.decode_jpeg(data), quality=50)
    for ci, comp in ((1, cb), (2, cr)):
        deq = comp * qt.astype(np.float64)
        px = np.asarray(J.jpeg_decode(jnp.asarray(deq[None]),
                                      scaled=False))[0]
        rep = np.repeat(np.repeat(px, 2, 0), 2, 1) / 128.0
        ref = np.asarray(J.jpeg_encode(jnp.asarray(rep[None]), quality=50,
                                       scaled=True))[0]
        assert np.abs(got[:, :, ci] - ref).max() < 1e-5, ci


def test_grayscale_file_into_3channel_network():
    rng = np.random.default_rng(5)
    img = np.clip(rng.normal(size=(16, 16)) * 0.3, -1, 127 / 128.0)
    data = enc.encode_pixels(img, quality=50)
    out = nm.normalize_image(bs.decode_jpeg(data), quality=50, channels=3)
    assert out.shape == (2, 2, 3, 64)
    assert np.array_equal(out[:, :, 0], out[:, :, 1])
