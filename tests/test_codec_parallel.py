"""Parallel-vs-sequential decode parity.

The lockstep vector decoder (``codec.lockstep``), the sharded worker
pool, and the overlapped ``ingest_pipeline`` are *performance* paths:
every one of them must be bit-exact with the scalar reference decoder
(``bitstream.decode_scan``) and produce identical ``IngestStats`` —
across the committed fixtures, property round-trips with varied DRI
intervals, the 1-segment no-DRI degenerate case, and error streams
(pool exceptions must propagate, not poison the batch silently).
"""
import os
import threading
import time

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.codec import bitstream as bs
from repro.codec import encode as enc
from repro.codec import ingest as ing
from repro.codec import lockstep as lk

FIXDIR = os.path.join(os.path.dirname(__file__), "fixtures", "codec")
FIXTURES = ("gray_q80", "color_q85_420", "color_q75_dri",
            "color_q75_dri_trailing_rst")


def _fixture_bytes(name):
    with open(os.path.join(FIXDIR, name + ".jpg"), "rb") as f:
        return f.read()


def _smooth(shape, seed):
    rng = np.random.default_rng(seed)
    c, h, w = shape
    y = np.linspace(-1, 1, h)[None, :, None]
    x = np.linspace(-1, 1, w)[None, None, :]
    img = 0.5 * np.sin(3 * y + 2 * x) + rng.normal(0, 0.2, shape)
    return np.clip(img, -1.0, 127.0 / 128.0)


def _assert_bit_exact(a: bs.DecodedJpeg, b: bs.DecodedJpeg):
    assert len(a.coefficients) == len(b.coefficients)
    for ca, cb in zip(a.coefficients, b.coefficients):
        assert np.array_equal(ca, cb)


def _assert_stats_equal(a: ing.IngestStats, b: ing.IngestStats):
    assert a.images == b.images and a.blocks == b.blocks
    assert a.bytes_in == b.bytes_in
    assert np.array_equal(a.energy, b.energy)
    assert np.array_equal(a.occupancy, b.occupancy)


# ---------------------------------------------------------------------------
# lockstep decoder vs scalar reference
# ---------------------------------------------------------------------------


def test_lockstep_bit_exact_on_fixtures():
    scans = [bs.prepare_scan(_fixture_bytes(n)) for n in FIXTURES]
    ref = [bs.decode_scan(s) for s in scans]
    got = lk.decode_scans(scans)
    for r, g in zip(ref, got):
        _assert_bit_exact(r, g)


def test_lockstep_single_stream_no_dri():
    """A DRI-less file is one whole-file stream: below the lockstep
    threshold the auto path stays scalar, but forcing lockstep on a
    single stream must still be bit-exact."""
    data = _fixture_bytes("gray_q80")
    scan = bs.prepare_scan(data)
    assert scan.restart_interval == 0
    assert lk.count_streams([scan]) == 1
    _assert_bit_exact(bs.decode_scan(scan), lk.decode_scans([scan])[0])


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 5), st.integers(0, 2), st.integers(0, 99))
def test_lockstep_round_trip_varied_dri(dri, q, seed):
    """encode → decode round-trip parity at property-varied restart
    intervals (0 = no DRI), qualities, and gray/color layouts."""
    quality = (60, 75, 90)[q]
    if seed % 2:
        img = _smooth((1, 24, 32), seed)
    else:
        img = _smooth((3, 32, 48), seed)
    data = enc.encode_pixels(img, quality=quality, restart_interval=dri)
    scan = bs.prepare_scan(data)
    _assert_bit_exact(bs.decode_scan(scan), lk.decode_scans([scan])[0])


def test_lockstep_bad_stream_falls_back_per_image():
    """A corrupt stream in a batch reproduces the scalar decoder's
    exception without poisoning the other images."""
    good = _fixture_bytes("color_q75_dri")
    scan = bs.prepare_scan(good)
    # truncate the final segment's bits: lockstep flags the overrun and
    # re-runs that image on the scalar path, which raises
    broken = scan._replace(segments=tuple(
        list(scan.segments[:-1]) + [scan.segments[-1][:2]]))
    with pytest.raises(bs.JpegError):
        bs.decode_scan(broken)
    with pytest.raises(bs.JpegError):
        lk.decode_scans([broken])
    # the same broken scan next to healthy ones: decode_scans raises for
    # the batch (matching sequential semantics) — but healthy-only
    # batches that *flag* no error never take the fallback
    out = lk.decode_scans([scan, bs.prepare_scan(good)])
    _assert_bit_exact(bs.decode_scan(scan), out[0])


# ---------------------------------------------------------------------------
# ingest_batch parallel modes
# ---------------------------------------------------------------------------


def test_ingest_parallel_matches_sequential_on_fixtures():
    datas = [_fixture_bytes(n) for n in FIXTURES] * 2
    kw = dict(quality=50, grid=(5, 5), channels=3)
    seq, s_seq = ing.ingest_batch(datas, parallel=False, **kw)
    par, s_par = ing.ingest_batch(datas, parallel=True, **kw)
    auto, s_auto = ing.ingest_batch(datas, **kw)
    assert np.array_equal(seq, par) and np.array_equal(seq, auto)
    _assert_stats_equal(s_seq, s_par)
    _assert_stats_equal(s_seq, s_auto)
    # identical under merge_stats: per-half stats from the parallel path
    # merge to the same result as the sequential halves, bit-for-bit
    # (and agree with the whole-batch pass up to summation order)
    halves_par = [ing.ingest_batch(d, parallel=True, **kw)[1]
                  for d in (datas[:4], datas[4:])]
    halves_seq = [ing.ingest_batch(d, parallel=False, **kw)[1]
                  for d in (datas[:4], datas[4:])]
    m_par, m_seq = ing.merge_stats(halves_par), ing.merge_stats(halves_seq)
    _assert_stats_equal(m_par, m_seq)
    assert m_par.images == s_seq.images and m_par.blocks == s_seq.blocks
    assert np.allclose(m_par.energy, s_seq.energy)
    assert np.allclose(m_par.occupancy, s_seq.occupancy)


def test_ingest_pool_matches_sequential(monkeypatch):
    """Sharded pool decode (2 spawn workers) is bit-exact and
    order-preserving vs the in-process sequential reference."""
    datas = [_fixture_bytes(FIXTURES[i % len(FIXTURES)]) for i in range(6)]
    kw = dict(quality=50, grid=(5, 5), channels=3)
    seq, s_seq = ing.ingest_batch(datas, parallel=False, **kw)
    monkeypatch.setenv("JPEG_INGEST_WORKERS", "2")
    try:
        pool, s_pool = ing.ingest_batch(datas, **kw)
    finally:
        ing.shutdown_pool()
    assert np.array_equal(seq, pool)
    _assert_stats_equal(s_seq, s_pool)


def test_ingest_pool_exception_propagates(monkeypatch):
    """A worker raising mid-shard surfaces the original JpegError at the
    caller (through the future), not a pool plumbing error."""
    datas = [_fixture_bytes("gray_q80"), b"\x00not a jpeg",
             _fixture_bytes("color_q85_420"), _fixture_bytes("gray_q80")]
    monkeypatch.setenv("JPEG_INGEST_WORKERS", "2")
    try:
        with pytest.raises(bs.JpegError):
            ing.ingest_batch(datas, quality=50, grid=(5, 5), channels=3)
    finally:
        ing.shutdown_pool()


def test_ingest_workers_env_pins_sequential(monkeypatch):
    """JPEG_INGEST_WORKERS=1 keeps everything in-process: no pool is
    ever constructed (the CI sequential-fallback job relies on this)."""
    monkeypatch.setenv("JPEG_INGEST_WORKERS", "1")
    assert ing.ingest_workers() == 1
    datas = [_fixture_bytes(n) for n in FIXTURES]
    seq, _ = ing.ingest_batch(datas, quality=50, grid=(5, 5), channels=3,
                              parallel=False)
    par, _ = ing.ingest_batch(datas, quality=50, grid=(5, 5), channels=3)
    assert np.array_equal(seq, par)
    assert ing._POOL is None


# ---------------------------------------------------------------------------
# ingest_pipeline (decode/compute overlap)
# ---------------------------------------------------------------------------


def test_ingest_pipeline_parity_and_order():
    datas = [_fixture_bytes(FIXTURES[i % len(FIXTURES)]) for i in range(8)]
    kw = dict(quality=50, grid=(5, 5), channels=3)
    ref, _ = ing.ingest_batch(datas, parallel=False, **kw)
    outs = list(ing.ingest_pipeline([datas[:4], datas[4:6], datas[6:]],
                                    depth=2, **kw))
    assert [o[0].shape[0] for o in outs] == [4, 2, 2]
    assert np.array_equal(np.concatenate([o[0] for o in outs]), ref)


def test_ingest_pipeline_close_joins_producer():
    """The prefetch lifecycle contract: a consumer walking away joins the
    decode thread instead of leaking it."""
    datas = [_fixture_bytes("gray_q80")] * 2

    def batches():
        while True:
            yield datas

    before = threading.active_count()
    gen = ing.ingest_pipeline(batches(), depth=2, quality=50,
                              grid=(5, 5), channels=3)
    batch, _ = next(gen)
    assert batch.shape[0] == 2
    gen.close()
    deadline = time.time() + 5.0
    while threading.active_count() > before and time.time() < deadline:
        time.sleep(0.01)
    assert threading.active_count() == before, "decode thread leaked"


def test_ingest_pipeline_propagates_decode_error():
    bad = [[_fixture_bytes("gray_q80")], [b"\xff\xd8 broken"]]
    gen = ing.ingest_pipeline(bad, depth=2, quality=50, grid=(5, 5),
                              channels=3)
    next(gen)
    with pytest.raises(bs.JpegError):
        next(gen)
